"""Paper Figure 3 reproduction: loss f as a function of the protocol
probability p and the personalization strength lambda (uncompressed L2GD,
logistic regression, 5 clients) — prints an ASCII heatmap.

The whole (p, lambda) grid runs as ONE compiled dispatch through the
scanned rollout engine (repro.core.rollout.rollout_l2gd_grid): every
cell's K protocol rounds live inside a vmapped lax.scan, so there are no
per-step host round-trips and no Python double loop over the grid.

With ``--alpha`` the synthetic per-client draws are pooled and
re-partitioned by LABEL SKEW — a Dirichlet(alpha) split of each class
across clients (repro.data.partition.dirichlet_partition), the standard
federated non-IID benchmark protocol.  Small alpha (e.g. 0.1) gives
near-single-class clients, where personalization should pay off most;
large alpha approaches IID.

  PYTHONPATH=src python examples/personalization_sweep.py [--full]
  PYTHONPATH=src python examples/personalization_sweep.py --alpha 0.1
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hyper_grid, rollout_l2gd_grid
from repro.data import logreg_loss_and_grad, make_logreg_data
from repro.data.partition import dirichlet_partition

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="finer grid, K=300")
ap.add_argument("--K", type=int, default=None)
ap.add_argument("--alpha", type=float, default=None,
                help="non-IID mode: pool the samples and re-split them by a "
                     "per-class Dirichlet(alpha) draw (label skew); smaller "
                     "= more heterogeneous")
args = ap.parse_args()

N = 5
data = make_logreg_data(n_clients=N, heterogeneity=1.5, seed=0)
X, Y = jnp.asarray(data.features), jnp.asarray(data.labels)
if args.alpha is not None:
    # pool every client's draws, then hand out label-skewed shards; each
    # client is resampled to a FIXED m rows so the (N, m, d) stacked
    # layout (and the one-dispatch grid rollout) is unchanged
    Xp = np.asarray(data.features).reshape(-1, data.features.shape[-1])
    Yp = np.asarray(data.labels).reshape(-1)
    parts = dirichlet_partition(Yp, N, alpha=args.alpha, seed=0)
    m = Xp.shape[0] // N
    rng = np.random.default_rng(0)
    rows = [rng.choice(p, size=m, replace=len(p) < m) for p in parts]
    X = jnp.asarray(np.stack([Xp[r] for r in rows]).astype(np.float32))
    Y = jnp.asarray(np.stack([Yp[r] for r in rows]).astype(np.float32))
    share = [float(np.mean(np.asarray(Y[c]) > 0)) for c in range(N)]
    print(f"Dirichlet(alpha={args.alpha}) label skew — share of +1 per "
          "client: " + ", ".join(f"{s:.2f}" for s in share))
K = args.K or (300 if args.full else 100)
ps = np.linspace(0.1, 0.9, 9) if args.full else [0.1, 0.25, 0.4, 0.65, 0.9]
lams = [0.01, 0.1, 1, 5, 10, 25, 100] if args.full else [0.1, 1, 10, 100]


def grad_fn(p, b):
    loss, g = logreg_loss_and_grad(p["w"], b[0], b[1], 0.01)
    return loss, {"w": g}


# stability rule: keep the aggregation contraction eta*lam/(np) <= 1
hp_grid, gshape = hyper_grid(ps, lams,
                             lambda P, L: np.minimum(0.4, N * P / L), N)
finals, trace = rollout_l2gd_grid(
    jax.random.PRNGKey(0), {"w": jnp.zeros((N, 124))}, hp_grid, (X, Y),
    batch_axis=None, steps=K, grad_fn=grad_fn)
w = np.asarray(finals.params["w"]).reshape(gshape + (N, 124))

grid = np.zeros(gshape)
for i in range(len(ps)):
    for j in range(len(lams)):
        grid[i, j] = np.mean([
            logreg_loss_and_grad(w[i, j, c], X[c], Y[c])[0]
            for c in range(N)])

print(f"\nmean local loss f after K={K} iterations (lower = better)\n")
print("         " + "".join(f"lam={l:<8g}" for l in lams))
lo, hi = grid.min(), grid.max()
shades = " .:-=+*#%@"
for i, p in enumerate(ps):
    cells = "".join(f"{grid[i, j]:<12.4f}" for j in range(len(lams)))
    bar = "".join(shades[min(int((grid[i, j] - lo) / (hi - lo + 1e-12) * 9),
                             9)] for j in range(len(lams)))
    print(f"p={p:<6.2f} {cells} |{bar}|")

bi, bj = np.unravel_index(grid.argmin(), grid.shape)
print(f"\noptimum: p={ps[bi]}, lambda={lams[bj]} (f={grid[bi, bj]:.4f}) — "
      "an interior optimum, as the paper's Fig. 3 takeaway predicts.")
