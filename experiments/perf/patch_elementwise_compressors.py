"""§Perf iteration 1 patch: make elementwise compressors (natural, bernoulli,
identity) apply WITHOUT flattening, so model-axis-sharded parameters are
compressed shard-locally and the SPMD partitioner stops all-gathering full
weight matrices in the aggregation branch.  Applied after the baseline
sweeps complete; see EXPERIMENTS.md §Perf."""
import re

path = "src/repro/core/compressors.py"
src = open(path).read()

src = src.replace('''@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base class. Subclasses implement _apply_flat on 1-D float32 arrays."""

    name: str = dataclasses.field(default="base", init=False)

    # -- public API ---------------------------------------------------------
    def apply(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Return C(x) with x of any shape; dtype preserved."""
        orig_dtype = x.dtype
        flat = x.reshape(-1).astype(jnp.float32)
        out = self._apply_flat(key, flat)
        return out.reshape(x.shape).astype(orig_dtype)''',
'''@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base class. Subclasses implement _apply_flat on float32 arrays
    (1-D unless ``elementwise``, in which case any shape)."""

    name: str = dataclasses.field(default="base", init=False)
    # elementwise operators skip the reshape(-1): under SPMD a flatten of a
    # model-axis-sharded weight forces an all-gather of the full matrix
    # before compression (observed in the baseline dry-run HLO, §Perf it.1)
    elementwise: bool = dataclasses.field(default=False, init=False)

    # -- public API ---------------------------------------------------------
    def apply(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Return C(x) with x of any shape; dtype preserved."""
        orig_dtype = x.dtype
        if self.elementwise:
            return self._apply_flat(key, x.astype(jnp.float32)).astype(orig_dtype)
        flat = x.reshape(-1).astype(jnp.float32)
        out = self._apply_flat(key, flat)
        return out.reshape(x.shape).astype(orig_dtype)''')

for cls in ("Identity", "Natural", "Bernoulli"):
    src = src.replace(
        f'    name: str = dataclasses.field(default="{cls.lower()}", init=False)\n',
        f'    name: str = dataclasses.field(default="{cls.lower()}", init=False)\n'
        f'    elementwise: bool = dataclasses.field(default=True, init=False)\n')

open(path, "w").write(src)
print("patched compressors.py")
